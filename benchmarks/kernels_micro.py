"""Microbenchmarks for the QSGD kernels and the packed wire format.

Two tiers:

  * kernel/* rows — raw transform throughput at vector sizes n (off-TPU the
    Pallas kernels are bypassed for the bit-identical vectorized-jnp path, so
    these prove correctness-path throughput; TPU timing comes from the
    roofline analysis).  The packed rows also report the actual wire payload
    in bytes — the number the CommLedger charges (pinned by test_ledger.py).
  * round/* rows — a real Fed-CHS round (scanned driver, steady-state) with
    the packed QSGDChannel vs the pre-packing baseline where the cross-device
    values stay dense f32 arrays.  This is the gated comparison: packing adds
    shift/mask arithmetic per element, which at the *round* level must
    disappear into the training compute.  `benchmarks/run.py --json` fails if
    the packed round drops below 0.8x of the dense-code baseline (0.8, not
    1.0: shared-runner timing noise on few-ms rounds; the structural claim is
    parity, the wire win is the 6.4x payload shrink the derived field shows).
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp

from repro.comm.bits import qsgd_message_bits
from repro.comm.channels import QSGDChannel
from repro.kernels.ops import (
    DEFAULT_BLOCK,
    _pad_to_blocks,
    qsgd_decode,
    qsgd_encode,
    qsgd_quantize,
    qsgd_roundtrip,
)
from repro.kernels.qsgd import ROWS_PER_TILE
from repro.kernels.ref import qsgd_quantize_blocks_ref


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


@functools.partial(jax.jit, static_argnames=("s",))
def _quantize_threefry(v, key, *, s):
    """The pre-optimization qsgd_quantize (threefry-uniform dither), timed
    alongside the shipped path so the quantize row's derived field records
    the dither swap's before/after in-run: `GB/s=<now>_was_<threefry>`."""
    blocks, n = _pad_to_blocks(v, DEFAULT_BLOCK, ROWS_PER_TILE)
    u = jax.random.uniform(key, blocks.shape, jnp.float32)
    q, norms = qsgd_quantize_blocks_ref(blocks, u, s)
    return q, norms, n


@dataclasses.dataclass(frozen=True)
class DenseCodeQSGDChannel:
    """The pre-packing baseline: identical QSGD math, but the cross-device
    value stays a dense f32 array (codes never leave float registers) — what
    QSGDChannel transported before the packed integer wire format."""

    levels: int = 16
    stochastic: bool = dataclasses.field(default=True, init=False)
    per_message: bool = dataclasses.field(default=True, init=False)

    def compress(self, tree, key):
        leaves, treedef = jax.tree.flatten(tree)
        keys = jax.random.split(key, len(leaves))
        out = [
            qsgd_roundtrip(leaf, k, s=self.levels).astype(leaf.dtype)
            for leaf, k in zip(leaves, keys)
        ]
        return jax.tree.unflatten(treedef, out)

    def message_bits(self, num_params: int) -> int:
        return qsgd_message_bits(num_params, self.levels)


def _round_us(task, cfg) -> float:
    from repro.core import run_fed_chs

    run_fed_chs(task, cfg)  # compile + warm the (model, channel) cache
    t0 = time.time()
    run_fed_chs(task, cfg)
    return (time.time() - t0) / cfg.rounds * 1e6


def run(quick: bool = True):
    rows = []
    key = jax.random.PRNGKey(0)
    s = 16
    for n in (1 << 16, 1 << 20) if quick else (1 << 16, 1 << 20, 1 << 24):
        v = jax.random.normal(key, (n,), jnp.float32)
        us_q = _time(lambda x: qsgd_quantize(x, key, s=s), v)
        us_q_old = _time(lambda x: _quantize_threefry(x, key, s=s), v)
        us_rt = _time(lambda x: qsgd_roundtrip(x, key, s=s), v)
        gbps = n * 4 / (us_q / 1e6) / 1e9
        gbps_old = n * 4 / (us_q_old / 1e6) / 1e9
        rows.append((f"kernel/qsgd_quantize_n{n}", us_q,
                     f"GB/s={gbps:.2f}_was_{gbps_old:.2f}"))
        rows.append((f"kernel/qsgd_roundtrip_n{n}", us_rt, ""))

        # packed wire: fused quantize->pack and unpack->dequantize
        us_enc = _time(lambda x: qsgd_encode(x, key, s=s), v)
        wire = qsgd_encode(v, key, s=s)
        us_dec = _time(lambda w: qsgd_decode(w, s=s, shape=(n,)), wire)
        payload_bytes = wire["payload"].size * 4 + wire["norms"].size * 4
        ratio = n * 4 / payload_bytes
        rows.append((f"kernel/qsgd_encode_n{n}", us_enc,
                     f"payload_B={payload_bytes}"))
        rows.append((f"kernel/qsgd_decode_n{n}", us_dec,
                     f"{ratio:.2f}x_compression_vs_f32"))
        print(f"  qsgd n={n:>9d}: quantize {us_q:10.0f} us  roundtrip "
              f"{us_rt:10.0f} us  encode {us_enc:10.0f} us  decode "
              f"{us_dec:10.0f} us  ({ratio:.1f}x smaller wire)")

    # round-level head-to-head: packed wire vs dense-code baseline inside the
    # scanned Fed-CHS driver (this ratio is the perf gate in run.py --json)
    from benchmarks.common import BenchScale, build_task
    from repro.core import FedCHSConfig

    scale = BenchScale(train_size=2000, test_size=400, rounds=8 if quick else 30,
                       local_steps=5, eval_every=100, batch_size=8)
    task = build_task("mnist", "mlp", 0.6, scale)
    def mk(ch):
        return FedCHSConfig(rounds=scale.rounds, local_steps=scale.local_steps,
                            local_epochs=5, eval_every=scale.eval_every,
                            channel=ch, seed=0)
    us_dense_code = _round_us(task, mk(DenseCodeQSGDChannel(s)))
    us_packed = _round_us(task, mk(QSGDChannel(s)))
    speedup = us_dense_code / us_packed
    rows.append(("round/fed_chs_dense_code_qsgd", us_dense_code, ""))
    rows.append(("round/fed_chs_packed_qsgd", us_packed,
                 f"{speedup:.2f}x_vs_dense_code_qsgd"))
    print(f"  fed_chs round: dense-code {us_dense_code:.0f} us  packed "
          f"{us_packed:.0f} us  ({speedup:.2f}x)")
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
