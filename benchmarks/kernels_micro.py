"""Microbenchmarks for the Pallas QSGD kernel (interpret mode on CPU; the
numbers prove correctness-path throughput, not TPU perf — TPU timing comes
from the roofline analysis)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.ops import qsgd_quantize, qsgd_roundtrip


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def run(quick: bool = True):
    rows = []
    key = jax.random.PRNGKey(0)
    for n in (1 << 16, 1 << 20) if quick else (1 << 16, 1 << 20, 1 << 24):
        v = jax.random.normal(key, (n,), jnp.float32)
        us_q = _time(lambda x: qsgd_quantize(x, key, s=16), v)
        us_rt = _time(lambda x: qsgd_roundtrip(x, key, s=16), v)
        gbps = n * 4 / (us_q / 1e6) / 1e9
        rows.append((f"kernel/qsgd_quantize_n{n}", us_q, f"GB/s={gbps:.2f}"))
        rows.append((f"kernel/qsgd_roundtrip_n{n}", us_rt, ""))
        print(f"  qsgd n={n:>9d}: quantize {us_q:10.0f} us  roundtrip {us_rt:10.0f} us")
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
