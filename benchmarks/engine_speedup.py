"""Wall-clock win of the fused execution layers over the seed loop structure
(per-interaction batch staging + `float()` host syncs + Python per-cluster
loops + interpret-mode QSGD off-TPU).

Per-round head-to-heads on the default synthetic task, identical math:

  * Hier-Local-QSGD global round — seed style runs interactions x clusters
    separate jit dispatches with a host sync after each; the engine runs one
    fused scan-over-interactions vmapped over all clusters.
  * Fed-CHS E=5 + QSGD round — seed style stages E batches and syncs per
    interaction; the engine stages the round once and scans.

The seed arms reproduce the seed behavior faithfully, including its QSGD
routing: off-TPU the seed executed the Pallas kernels in interpret mode (a
grid-step loop of dynamic slices); this PR routes off-TPU QSGD through the
bit-identical fused-XLA oracle (`kernels/ref.py`) instead, and that rerouting
is part of the measured win.

Whole-run arms (the `scanned` rows, measured at 200 rounds on the edge-scale
synthetic task — see `BenchScale.edge`): the scanned executor
(`scan_rounds=True`, the default) vs the looped driver (`scan_rounds=False`)
vs the seed-style loop, plus a 4-seed vmapped `run_sweep` vs sequential
looped runs.  All arms are steady-state (each is fully warmed before timing,
so compile time is excluded).  Honest reading of the numbers on this 2-core
CPU container: per-round model compute floors at a few ms even for tiny
batches, so removing the per-round host work (dispatch, staging transfers,
scheduler/ledger Python) buys ~1.2-1.4x on host-bound scenarios and ~1.0x on
compute-bound ones, while the win over the seed-style loop structure
compounds to >=2x; on a real accelerator the device time per round shrinks by
orders of magnitude and the host share — exactly what the scan removes —
becomes the bottleneck.

Usage:
  PYTHONPATH=src:. python benchmarks/engine_speedup.py [--rounds 8] [--full]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchScale, build_task
from repro.core import FedCHSConfig, run_fed_chs
from repro.core.baselines import HierLocalQSGDConfig, run_hier_local_qsgd
from repro.core.oracles import multi_client_local_sgd
from repro.core.simulation import FLTask
from repro.kernels.ops import DEFAULT_BLOCK, _pad_to_blocks
from repro.kernels.qsgd import ROWS_PER_TILE, qsgd_dequantize_blocks, qsgd_quantize_blocks
from repro.optim.schedules import paper_sqrt_schedule
from repro.utils import tree_add


# --------------------------------------------------------------------------
# seed-style reference loops (the pre-engine structure, kept here verbatim
# so the benchmark keeps measuring the same baseline as the repo evolves)
# --------------------------------------------------------------------------


def _seed_qsgd_roundtrip(v: jnp.ndarray, key: jax.Array, *, s: int) -> jnp.ndarray:
    """The seed's QSGD path: Pallas kernels, which off-TPU run in interpret
    mode — exactly what `qsgd_roundtrip` dispatched to before this PR."""
    blocks, _ = _pad_to_blocks(v, DEFAULT_BLOCK, ROWS_PER_TILE)
    u = jax.random.uniform(key, blocks.shape, jnp.float32)
    q, norms = qsgd_quantize_blocks(blocks, u, s=s)
    flat = qsgd_dequantize_blocks(q, norms, s=s).reshape(-1)
    return flat[: v.size].reshape(v.shape)


def qsgd_compress_tree(tree, key, *, s: int):
    """Seed-style leaf-wise compress over the interpret-mode kernels."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    out = [
        _seed_qsgd_roundtrip(leaf, k, s=s).astype(leaf.dtype)
        for leaf, k in zip(leaves, keys)
    ]
    return jax.tree.unflatten(treedef, out)


def seed_style_hier(task: FLTask, config: HierLocalQSGDConfig) -> None:
    task.reset_loaders(config.seed)
    K, E = config.local_steps, config.local_epochs
    interactions = K // E
    sched_fn = config.schedule or paper_sqrt_schedule(K, half=False)
    lrs = np.asarray([sched_fn(k) for k in range(K)], dtype=np.float32)

    params = task.init_params()
    multi_local = multi_client_local_sgd(task.model)
    key = jax.random.PRNGKey(config.seed + 1)
    M = task.num_clusters
    cluster_gammas = [jnp.asarray(task.cluster_weights(m)) for m in range(M)]
    es_weights = jnp.asarray(
        np.array(task.cluster_sizes, dtype=np.float32) / sum(task.cluster_sizes)
    )

    for _t in range(config.rounds):
        cluster_params = [params] * M
        loss_acc = 0.0
        for j in range(interactions):
            lr_slice = jnp.asarray(lrs[j * E : (j + 1) * E])
            for m in range(M):
                b = task.sample_cluster_batches(m, E)
                xs = jnp.swapaxes(b["x"], 0, 1)
                ys = jnp.swapaxes(b["y"], 0, 1)
                new_p, losses = multi_local(cluster_params[m], xs, ys, lr_slice)
                deltas = jax.tree.map(
                    lambda np_, op: np_ - op[None], new_p, cluster_params[m]
                )
                if config.qsgd_levels is not None:
                    key, sub = jax.random.split(key)
                    deltas = qsgd_compress_tree(deltas, sub, s=config.qsgd_levels)
                agg = jax.tree.map(
                    lambda dl, g=cluster_gammas[m]: jnp.einsum("n,n...->...", g, dl),
                    deltas,
                )
                cluster_params[m] = tree_add(cluster_params[m], agg)
                loss_acc += float(jnp.mean(losses))  # the per-interaction host sync
        es_deltas = []
        for m in range(M):
            delta = jax.tree.map(lambda a, b: a - b, cluster_params[m], params)
            if config.qsgd_levels is not None:
                key, sub = jax.random.split(key)
                delta = qsgd_compress_tree(delta, sub, s=config.qsgd_levels)
            es_deltas.append(delta)
        stacked = jax.tree.map(lambda *xs_: jnp.stack(xs_), *es_deltas)
        agg = jax.tree.map(lambda x: jnp.einsum("m,m...->...", es_weights, x), stacked)
        params = tree_add(params, agg)
    jax.block_until_ready(jax.tree.leaves(params)[0])


def seed_style_fed_chs(task: FLTask, config: FedCHSConfig) -> None:
    task.reset_loaders(config.seed)
    K, E = config.local_steps, config.local_epochs
    interactions = K // E
    sched_fn = config.schedule or paper_sqrt_schedule(K, half=False)
    lrs = np.array([sched_fn(k) for k in range(K)], dtype=np.float32)

    params = task.init_params()
    multi_local = multi_client_local_sgd(task.model)
    key = jax.random.PRNGKey(config.seed + 1)
    m = 0
    for t in range(config.rounds):
        gammas = jnp.asarray(task.cluster_weights(m))
        for j in range(interactions):
            lr_slice = jnp.asarray(lrs[j * E : (j + 1) * E])
            b = task.sample_cluster_batches(m, E)
            xs = jnp.swapaxes(b["x"], 0, 1)
            ys = jnp.swapaxes(b["y"], 0, 1)
            new_p, losses = multi_local(params, xs, ys, lr_slice)
            deltas = jax.tree.map(lambda np_, op: np_ - op[None], new_p, params)
            if config.qsgd_levels is not None:
                key, sub = jax.random.split(key)
                deltas = qsgd_compress_tree(deltas, sub, s=config.qsgd_levels)
            agg = jax.tree.map(lambda dl: jnp.einsum("n,n...->...", gammas, dl), deltas)
            params = tree_add(params, agg)
            float(jnp.mean(losses))  # the per-interaction host sync
        m = (m + 1) % task.num_clusters
    jax.block_until_ready(jax.tree.leaves(params)[0])


def _timed(fn, *args) -> float:
    t0 = time.perf_counter()
    fn(*args)
    return time.perf_counter() - t0


def _steady(fn, *args) -> float:
    """Steady-state wall-clock: one full warm call (compiles every chunk
    shape), then best-of-2 timed calls (2-core container timings are noisy)."""
    fn(*args)
    return min(_timed(fn, *args), _timed(fn, *args))


def _steady_pair(fn_a, fn_b, trials: int = 3) -> tuple[float, float]:
    """Best-of-`trials` for two arms with INTERLEAVED timed calls (a, b, a,
    b, ...).  For ratio gates (telemetry overhead) this cancels the slow
    machine-load drift that sequential best-of measurements pick up as a
    phantom regression on a shared 2-core container."""
    fn_a(), fn_b()  # warm/compile both arms
    best_a = best_b = float("inf")
    for _ in range(trials):
        best_a = min(best_a, _timed(fn_a))
        best_b = min(best_b, _timed(fn_b))
    return best_a, best_b


def whole_run(quick: bool = True) -> list[tuple[str, float, str]]:
    """Whole-run arms: scanned executor vs looped driver vs seed-style loop,
    plus the vmapped multi-seed sweep.  200 rounds, edge-scale task (quick)
    or the standard quick-scale task (--full: the compute-bound regime,
    reported for honesty — the scan can't beat the FLOP floor)."""
    import dataclasses

    from repro.core import run_sweep
    from repro.core.baselines import WRWGDConfig, run_wrwgd

    scale = BenchScale.edge() if quick else BenchScale()
    task = build_task("mnist", "mlp", 0.6, scale)
    R = 200
    rows = []

    def report(name, t_scan, t_ref, ref_label):
        speed = t_ref / t_scan
        rows.append((name, t_scan / R * 1e6, f"{speed:.2f}x_vs_{ref_label}"))
        print(f"{name:32s} {t_ref / R * 1e3:8.1f} ms/round -> "
              f"{t_scan / R * 1e3:6.1f} ms/round  ({speed:.2f}x)")

    # --- Fed-CHS grad mode (paper E=1 dense), scanned vs looped driver ----
    grad_cfg = lambda **kw: FedCHSConfig(  # noqa: E731
        rounds=R, local_steps=max(scale.local_steps // 2, 1),
        eval_every=10_000, **kw)
    t_scan = _steady(run_fed_chs, task, grad_cfg())
    t_loop = _steady(run_fed_chs, task, grad_cfg(scan_rounds=False))
    report("scanned_fed_chs_grad", t_scan, t_loop, "looped_driver")

    # --- WRWGD (1 client/round: the most host-bound driver) --------------
    walk_cfg = lambda **kw: WRWGDConfig(  # noqa: E731
        rounds=R, local_steps=scale.local_steps, eval_every=10_000, **kw)
    t_scan_w = _steady(run_wrwgd, task, walk_cfg())
    t_loop_w = _steady(run_wrwgd, task, walk_cfg(scan_rounds=False))
    report("scanned_wrwgd", t_scan_w, t_loop_w, "looped_driver")

    # --- Fed-CHS E=5 + QSGD, scanned vs looped AND vs the seed-style loop
    # (the seed arm's per-round cost is constant, so it is timed over 20
    # rounds; the scanned/looped arms run the full 200) ---------------------
    qsgd_cfg = lambda r, **kw: FedCHSConfig(  # noqa: E731
        rounds=r, local_steps=scale.local_steps, local_epochs=5,
        qsgd_levels=16, eval_every=10_000, **kw)
    t_scan_q = _steady(run_fed_chs, task, qsgd_cfg(R))
    t_loop_q = _steady(run_fed_chs, task, qsgd_cfg(R, scan_rounds=False))
    seed_style_fed_chs(task, qsgd_cfg(2))
    t_seed_q = _timed(seed_style_fed_chs, task, qsgd_cfg(20)) / 20 * R
    report("scanned_fed_chs_e5_qsgd", t_scan_q, t_loop_q, "looped_driver")
    report("scanned_fed_chs_e5_qsgd_seed", t_scan_q, t_seed_q, "seed_loop")

    # --- telemetry overhead: the SAME scanned E=5+QSGD run with in-graph
    # taps + host spans on (fresh RunTelemetry per call — it accumulates).
    # run.py --json gates this row: the tapped run must stay within ~10% of
    # the untapped one (speedup >= 0.91x), i.e. observability is cheap
    # enough to leave on --------------------------------------------------
    from repro.obs import RunTelemetry

    # interleaved pair: the ratio is gated, so both arms must see the same
    # machine conditions — comparing against the t_scan_q measured a minute
    # earlier turns background load drift into a phantom regression
    t_base, t_taps = _steady_pair(
        lambda: run_fed_chs(task, qsgd_cfg(R)),
        lambda: run_fed_chs(task, qsgd_cfg(R, obs=RunTelemetry())))
    report("scanned_fed_chs_telemetry", t_taps, t_base, "untapped")

    # --- vmapped 4-seed sweep vs 4 sequential looped runs (per-run time) --
    seeds = (0, 1, 2, 3)
    cfg = grad_cfg()
    t_sweep = _steady(run_sweep, task, cfg, seeds)

    def _sequential():
        for s in seeds:
            run_fed_chs(task, dataclasses.replace(cfg, seed=s, scan_rounds=False))

    t_seq = _steady(_sequential)
    speed = t_seq / t_sweep
    rows.append(("sweep_fed_chs_4seeds", t_sweep / len(seeds) / R * 1e6,
                 f"{speed:.2f}x_vs_sequential_looped"))
    print(f"{'sweep_fed_chs_4seeds':32s} {t_seq / len(seeds):8.2f} s/run -> "
          f"{t_sweep / len(seeds):6.2f} s/run  ({speed:.2f}x)")
    return rows


def run(quick: bool = True, rounds: int = 8) -> list[tuple[str, float, str]]:
    """benchmarks/run.py suite entry: returns (name, us_per_round, speedup) rows."""
    if rounds < 1:
        raise SystemExit("--rounds must be >= 1")
    scale = BenchScale() if quick else BenchScale.paper()
    task = build_task("mnist", "mlp", 0.6, scale)
    R = rounds

    results = {}

    # --- Hier-Local-QSGD global rounds (scan_rounds=False: these arms
    # measure the per-round engine vs the seed loop; the whole-run scan layer
    # is measured separately below) ----------------------------------------
    hier_cfg = lambda rounds: HierLocalQSGDConfig(  # noqa: E731
        rounds=rounds, local_steps=scale.local_steps, local_epochs=5,
        qsgd_levels=16, eval_every=10_000, scan_rounds=False)
    seed_style_hier(task, hier_cfg(1))                      # compile/warm
    t_seed = _timed(seed_style_hier, task, hier_cfg(R))
    run_hier_local_qsgd(task, hier_cfg(1))                  # compile/warm
    t_eng = _timed(run_hier_local_qsgd, task, hier_cfg(R))
    results["hier_local_qsgd"] = (t_seed / R, t_eng / R)

    # --- Fed-CHS E=5 + QSGD rounds ---------------------------------------
    chs_cfg = lambda rounds: FedCHSConfig(  # noqa: E731
        rounds=rounds, local_steps=scale.local_steps, local_epochs=5,
        qsgd_levels=16, eval_every=10_000, scan_rounds=False)
    seed_style_fed_chs(task, chs_cfg(1))
    t_seed = _timed(seed_style_fed_chs, task, chs_cfg(R))
    run_fed_chs(task, chs_cfg(1))
    t_eng = _timed(run_fed_chs, task, chs_cfg(R))
    results["fed_chs_e5_qsgd"] = (t_seed / R, t_eng / R)

    print(f"\nengine speedup — mnist/mlp, {scale.num_clients} clients, "
          f"{scale.num_clusters} clusters, K={scale.local_steps}, {R} timed rounds")
    print(f"{'workload':20s} {'seed loop ms/round':>19s} {'engine ms/round':>16s} {'speedup':>8s}")
    for name, (a, b) in results.items():
        print(f"{name:20s} {a * 1e3:19.1f} {b * 1e3:16.1f} {a / b:7.1f}x")
    worst = min(a / b for a, b in results.values())
    print(f"\nworst-case speedup: {worst:.1f}x "
          f"({'meets' if worst >= 2 else 'BELOW'} the >=2x acceptance bar)")
    rows = [
        (f"engine_{name}", b * 1e6, f"{a / b:.1f}x_vs_seed_loop")
        for name, (a, b) in results.items()
    ]

    print(f"\nwhole-run execution — {'edge' if quick else 'quick'}-scale task, "
          f"200 rounds, steady-state (compile excluded)")
    rows += whole_run(quick=quick)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8, help="timed rounds per arm")
    ap.add_argument("--full", action="store_true", help="paper-scale task")
    args = ap.parse_args()
    run(quick=not args.full, rounds=args.rounds)


if __name__ == "__main__":
    main()
