"""Paper Fig. 4: fully vs partially heterogeneous data.

Partial heterogeneity (clusters IID across, clients non-IID within) should
close the gap to the fully-heterogeneous run as T grows (Remark 4.2's
Delta_m -> 0 argument)."""
from __future__ import annotations

import time

from benchmarks.common import BenchScale
from repro.core import FedCHSConfig, FLTask, run_fed_chs
from repro.data import make_dataset
from repro.data.partition import dirichlet_partition, partial_heterogeneity_partition, assign_clusters
from repro.models.classifier import make_classifier


def run(quick: bool = True):
    scale = BenchScale(rounds=30)
    ds = make_dataset("mnist", train_size=scale.train_size, test_size=scale.test_size, seed=0)
    clf = make_classifier("mlp", "mnist", ds.spec.image_shape, 10)
    rows = []

    # fully heterogeneous
    clients_f = dirichlet_partition(ds.train_y, scale.num_clients, 0.3, seed=0)
    clusters_f = assign_clusters(scale.num_clients, scale.num_clusters, seed=0)
    task_f = FLTask(clf, ds, clients_f, clusters_f, batch_size=32, seed=0)
    t0 = time.time()
    res_f = run_fed_chs(task_f, FedCHSConfig(rounds=scale.rounds, local_steps=10, eval_every=5))
    w_f = time.time() - t0

    # partially heterogeneous (clusters IID)
    clients_p, clusters_p = partial_heterogeneity_partition(
        ds.train_y, scale.num_clients, scale.num_clusters, 0.3, seed=0
    )
    task_p = FLTask(clf, ds, clients_p, clusters_p, batch_size=32, seed=0)
    t0 = time.time()
    res_p = run_fed_chs(task_p, FedCHSConfig(rounds=scale.rounds, local_steps=10, eval_every=5))
    w_p = time.time() - t0

    print("\nFig. 4 (full vs partial heterogeneity, mnist/mlp λ=0.3):")
    print(f"  full    acc trace: {[round(a, 3) for a in res_f.test_acc]}")
    print(f"  partial acc trace: {[round(a, 3) for a in res_p.test_acc]}")
    gap = abs(res_f.final_acc() - res_p.final_acc())
    print(f"  final gap: {gap:.4f} (diminishes with T, Remark 4.2)")
    rows.append(("fig4/full_het", w_f / scale.rounds * 1e6, f"acc={res_f.final_acc():.4f}"))
    rows.append(("fig4/partial_het", w_p / scale.rounds * 1e6, f"acc={res_p.final_acc():.4f}"))
    rows.append(("fig4/gap", 0.0, f"gap={gap:.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
