"""Paper Table 1: test accuracy of Fed-CHS vs FedAvg / WRWGD / Hier-Local-QSGD
across datasets x models x Dirichlet(λ) ∈ {0.3, 0.6}.

Reduced scale by default (see benchmarks/common.py); the claim validated is
the *ordering*: Fed-CHS is competitive everywhere and ahead under stronger
heterogeneity — not the absolute accuracies (synthetic datasets, DESIGN.md §6).
"""
from __future__ import annotations

from benchmarks.common import ALGORITHMS, BenchScale, build_task, run_algorithm


def run(quick: bool = True):
    scale = BenchScale() if quick else BenchScale.paper()
    cells = (
        [("mnist", "mlp"), ("cifar10", "mlp"), ("mnist", "lenet")]
        if quick
        else [(d, m) for d in ("mnist", "cifar10", "cifar100") for m in ("mlp", "lenet")]
    )
    lams = (0.3, 0.6)
    rows = []
    table = {}
    for dataset, model in cells:
        for lam in lams:
            task = build_task(dataset, model, lam, scale)
            for alg in ALGORITHMS:
                res, wall = run_algorithm(alg, task, scale)
                acc = res.final_acc()
                table[(dataset, model, lam, alg)] = acc
                per_round_us = wall / max(len(res.rounds), 1) * 1e6
                rows.append((f"table1/{dataset}-{model}-lam{lam}-{alg}",
                             per_round_us, f"acc={acc:.4f}"))
    # ordering check: Fed-CHS within eps of the best under high heterogeneity
    print("\nTable 1 (reduced scale; accuracy):")
    hdr = f"{'dataset':10s} {'model':6s} {'λ':>4s} " + " ".join(f"{a:>16s}" for a in ALGORITHMS)
    print(hdr)
    for dataset, model in cells:
        for lam in lams:
            vals = " ".join(f"{table[(dataset, model, lam, a)]:16.4f}" for a in ALGORITHMS)
            print(f"{dataset:10s} {model:6s} {lam:4.1f} {vals}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
