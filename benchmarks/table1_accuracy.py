"""Paper Table 1: test accuracy of Fed-CHS vs FedAvg / WRWGD / Hier-Local-QSGD
across datasets x models x Dirichlet(λ) ∈ {0.3, 0.6}.

Reduced scale by default (see benchmarks/common.py); the claim validated is
the *ordering*: Fed-CHS is competitive everywhere and ahead under stronger
heterogeneity — not the absolute accuracies (synthetic datasets, DESIGN.md §6).

Multi-seed mode (`seeds > 1`, `--seeds` on the CLI): each cell reports
mean ± std across seeds, computed with ONE vmapped whole-run dispatch per
(cell, algorithm) via `repro.core.run_sweep` — the averaging regime
EdgeFLow/HiFlash report over, no longer N sequential runs.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import ALGORITHMS, BenchScale, algorithm_config, build_task


def _cell_accuracies(task, alg, scale, seeds: int) -> tuple[list[float], float, int]:
    """Final accuracy per seed + wall-clock + the algorithm's actual round
    count (each algorithm runs a different multiple of scale.rounds), via
    run_sweep when seeds > 1."""
    import time

    run, config = algorithm_config(alg, scale)
    t0 = time.time()
    if seeds == 1:
        results = [run(task, config)]
    else:
        from repro.core import run_sweep

        results = run_sweep(task, config, range(seeds))
    return [r.final_acc() for r in results], time.time() - t0, config.rounds


def run(quick: bool = True, seeds: int = 1):
    scale = BenchScale() if quick else BenchScale.paper()
    cells = (
        [("mnist", "mlp"), ("cifar10", "mlp"), ("mnist", "lenet")]
        if quick
        else [(d, m) for d in ("mnist", "cifar10", "cifar100") for m in ("mlp", "lenet")]
    )
    lams = (0.3, 0.6)
    rows = []
    table = {}
    for dataset, model in cells:
        for lam in lams:
            task = build_task(dataset, model, lam, scale)
            for alg in ALGORITHMS:
                accs, wall, alg_rounds = _cell_accuracies(task, alg, scale, seeds)
                table[(dataset, model, lam, alg)] = accs
                per_round_us = wall / max(alg_rounds * seeds, 1) * 1e6
                derived = (f"acc={np.mean(accs):.4f}" if seeds == 1 else
                           f"acc={np.mean(accs):.4f}±{np.std(accs):.4f}_{seeds}seeds")
                rows.append((f"table1/{dataset}-{model}-lam{lam}-{alg}",
                             per_round_us, derived))
    # ordering check: Fed-CHS within eps of the best under high heterogeneity
    print(f"\nTable 1 (reduced scale; accuracy, {seeds} seed(s)):")
    hdr = f"{'dataset':10s} {'model':6s} {'λ':>4s} " + " ".join(f"{a:>16s}" for a in ALGORITHMS)
    print(hdr)
    for dataset, model in cells:
        for lam in lams:
            vals = " ".join(
                f"{np.mean(table[(dataset, model, lam, a)]):16.4f}" for a in ALGORITHMS)
            print(f"{dataset:10s} {model:6s} {lam:4.1f} {vals}")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seeds", type=int, default=1,
                    help=">1: mean±std across seeds via one vmapped run_sweep "
                         "dispatch per cell")
    args = ap.parse_args()
    for r in run(quick=not args.full, seeds=args.seeds):
        print(",".join(map(str, r)))
