"""Paper Fig. 2: total communication (bits) to reach test accuracy Γ,
with and without QSGD compression, Fed-CHS vs FedAvg(+QSGD) vs Hier-Local-QSGD.

Two claims reproduced (§5.3):
  * structural — Fed-CHS needs NO parameter-server hop at all: its PS column
    is exactly 0 bits, while every baseline pays ES→PS or client→PS traffic
    (which the paper additionally calls out as multi-hop/long-distance);
  * total bits — with the paper's Fig.-2 configuration (E=5 local epochs per
    interaction, so K=20 in-cluster iterations cost only 4 uploads) and/or
    QSGD compression, Fed-CHS reaches Γ with the fewest total bits.

Eval granularity is uniform (every round) so bits-to-Γ is not quantised
differently across algorithms.
"""
from __future__ import annotations

import time

from benchmarks.common import BenchScale, build_task
from repro.core import FedCHSConfig, run_fed_chs
from repro.core.baselines import (
    FedAvgConfig,
    HierLocalQSGDConfig,
    run_fedavg,
    run_hier_local_qsgd,
)

GAMMA = {"mnist": 0.90, "cifar10": 0.55}

PS_HOPS = ("es_to_ps", "ps_to_es", "client_to_ps", "ps_to_client")


def _bits_split(res, gamma):
    """(edge_mbits, ps_mbits, total_mbits) accumulated up to the first round
    reaching gamma (None if never reached)."""
    r = res.rounds_to_accuracy(gamma)
    if r is None:
        return None, None, None
    total = res.ledger.bits_until(r)
    # hop split at end-of-run ratios (the per-round mix is constant per alg)
    ps_frac = sum(res.ledger.bits[h] for h in PS_HOPS) / max(res.ledger.total_bits(), 1)
    return total * (1 - ps_frac) / 1e6, total * ps_frac / 1e6, total / 1e6


def run(quick: bool = True):
    scale = BenchScale()
    rows = []
    print("\nFig. 2 (Mbits to reach Γ; '-' = not reached at this reduced scale):")
    print(f"{'dataset':9s} {'algorithm':22s} {'compressed':>10s} "
          f"{'edge_Mb':>9s} {'PS_Mb':>8s} {'total_Mb':>9s} {'final_acc':>9s}")
    datasets = ["mnist"] if quick else ["mnist", "cifar10"]
    for dataset in datasets:
        task = build_task(dataset, "lenet" if not quick else "mlp", 0.6, scale)
        gamma = GAMMA[dataset]

        def emit(name, tag, res, wall):
            edge, ps, total = _bits_split(res, gamma)
            def fmt(v):
                return f"{v:9.1f}" if v is not None else f"{'-':>9s}"
            print(f"{dataset:9s} {name:22s} {tag:>10s} {fmt(edge)} "
                  f"{fmt(ps)[:8]:>8s} {fmt(total)} {res.final_acc():9.4f}")
            rows.append((f"fig2/{dataset}-{name}-{tag}",
                         wall / max(len(res.rounds), 1) * 1e6,
                         f"mbits_to_gamma={None if total is None else round(total, 1)}"))

        for E, qsgd in ((1, None), (1, 16), (5, None), (5, 16)):
            t0 = time.time()
            res = run_fed_chs(task, FedCHSConfig(
                rounds=scale.rounds, local_steps=scale.local_steps,
                local_epochs=E, eval_every=1, qsgd_levels=qsgd, seed=0,
                track_events=False))
            emit(f"fed_chs(E={E})", "qsgd16" if qsgd else "dense",
                 res, time.time() - t0)
        for qsgd in (None, 16):
            t0 = time.time()
            res = run_fedavg(task, FedAvgConfig(
                rounds=max(scale.rounds // 4, 4), local_steps=scale.local_steps,
                eval_every=1, qsgd_levels=qsgd, seed=0, track_events=False))
            emit("fedavg", "qsgd16" if qsgd else "dense", res, time.time() - t0)
        t0 = time.time()
        res = run_hier_local_qsgd(task, HierLocalQSGDConfig(
            rounds=max(scale.rounds // 6, 2), local_steps=scale.local_steps,
            local_epochs=5, eval_every=1, qsgd_levels=16, seed=0,
            track_events=False))
        emit("hier_local_qsgd", "qsgd16", res, time.time() - t0)
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
