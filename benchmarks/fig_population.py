"""Population-scaling suite: the device-mesh sharded round engine.

`config.mesh` maps the scanned round engine's client/cluster axes onto a
("clusters", "clients") device mesh (`repro.sharding.fed`), bit-identically
to the single-device run (tests/test_sharding_fed.py).  This suite measures
what the mesh buys at population scale:

  * population/fedavg_round_{unsharded,sharded} — steady-state scanned round
    time at a fixed population, identical math.  The sharded arm's derived
    field is the gated ratio (`run.py --json` fails below 0.9x): on forced
    host devices sharing one CPU the structural claim is *parity* — same
    total FLOPs through one core, collectives must hide under the compute —
    while on a real mesh the client-axis FLOPs split D ways.
  * population/staged_batch_n{N} — the memory half, and the reason the mesh
    raises the max simulable population: per-device bytes of the staged
    per-chunk batch shard vs the global stack.  Each device holds 1/D of the
    client axis, so population capacity scales with mesh size instead of
    capping at one device's memory.
  * population/sweep_seed_sharded — `run_sweep(mesh=...)`: the vmapped
    multi-seed sweep's leading seed axis device-sharded (pure GSPMD).

Without >= 8 devices every arm falls back to single-device (derived
`single_device_fallback`, never gated).  Standalone usage forces 8 host
devices BEFORE jax initializes:

  PYTHONPATH=src:. python benchmarks/fig_population.py [--quick]

(standalone applies the 0.9x gate itself and exits nonzero on regression —
the CI sharding-smoke job runs exactly this).
"""
from __future__ import annotations

import os
import sys

if __name__ == "__main__":  # must precede any jax import
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses
import time

GATE = 0.9  # sharded round must stay within 10% of unsharded (see run.py)


def _per_device_bytes(tree) -> int:
    """Max bytes any single device holds of `tree` (addressable shards)."""
    per: dict = {}
    import jax

    for leaf in jax.tree.leaves(tree):
        for sh in leaf.addressable_shards:
            per[sh.device] = per.get(sh.device, 0) + sh.data.nbytes
    return max(per.values())


def _global_bytes(tree) -> int:
    import jax

    return sum(leaf.nbytes for leaf in jax.tree.leaves(tree))


def _population_task(num_clients: int, train_size: int, seed: int = 0):
    from repro.core.simulation import FLTask
    from repro.data import assign_clusters, dirichlet_partition, make_dataset
    from repro.models.classifier import make_classifier

    ds = make_dataset("mnist", train_size=train_size,
                      test_size=max(train_size // 5, 100), seed=seed)
    clients = dirichlet_partition(ds.train_y, num_clients, 0.6, seed=seed)
    clusters = assign_clusters(num_clients, 4, seed=seed)
    model = make_classifier("mlp", "mnist", ds.spec.image_shape, 10)
    # batch 16: puts the round in the compute-dominated regime where the
    # parity gate is meaningful — at tiny batches the client-delta gather
    # (pure memcpy on forced host devices) dominates and the ratio measures
    # memory bandwidth, not the engine (0.78x at batch 8 vs ~1.0x here)
    return FLTask(model, ds, clients, clusters, batch_size=16, seed=seed)


def _run_us(task, cfg, reps: int = 3) -> float:
    """Best-of-reps steady-state round time (min filters shared-runner noise,
    which only ever adds time)."""
    from repro.core.baselines import run_fedavg

    run_fedavg(task, cfg)  # compile + warm the engine caches
    best = float("inf")
    for _ in range(reps):
        t0 = time.time()
        run_fedavg(task, cfg)
        best = min(best, time.time() - t0)
    return best / cfg.rounds * 1e6


def _paired_us(task, cfg_a, cfg_b, reps: int = 3) -> tuple[float, float]:
    """Best-of-reps for two arms with INTERLEAVED timed calls (a, b, a, b,
    ...).  The sharded/unsharded ratio is a gate: sequential best-of
    measurements pick up slow machine-load drift on a shared container as a
    phantom (de)regression — interleaving cancels it (same fix as
    engine_speedup._steady_pair for the telemetry gate)."""
    from repro.core.baselines import run_fedavg

    run_fedavg(task, cfg_a)  # compile + warm both arms' engine caches
    run_fedavg(task, cfg_b)
    best = [float("inf"), float("inf")]
    for _ in range(reps):
        for i, cfg in enumerate((cfg_a, cfg_b)):
            t0 = time.time()
            run_fedavg(task, cfg)
            best[i] = min(best[i], time.time() - t0)
    return (best[0] / cfg_a.rounds * 1e6, best[1] / cfg_b.rounds * 1e6)


def run(quick: bool = True):
    import jax

    from repro.core.baselines import FedAvgConfig
    from repro.core.baselines.fedavg import _fedavg_scan_plan
    from repro.core.sweep import run_sweep
    from repro.launch.mesh import make_federation_mesh
    from repro.sharding.fed import resolve_mesh

    rows = []
    mesh = make_federation_mesh(2, 4)
    sharded = resolve_mesh(mesh) is not None  # False on < 8 devices
    n = 32
    rounds = 6 if quick else 24
    task = _population_task(n, 1024 if quick else 4096)
    cfg = FedAvgConfig(rounds=rounds, local_steps=8, eval_every=100,
                       chunk_rounds=rounds, seed=0)

    if sharded:
        us0, us1 = _paired_us(task, cfg, dataclasses.replace(cfg, mesh=mesh))
        rows.append(("population/fedavg_round_unsharded", us0, f"n={n}_clients"))
        speedup = us0 / us1
        rows.append(("population/fedavg_round_sharded", us1,
                     f"{speedup:.2f}x_vs_unsharded"))
        print(f"  fedavg round n={n}: unsharded {us0:.0f} us  sharded "
              f"{us1:.0f} us  ({speedup:.2f}x on {mesh.devices.size} devices)")
    else:
        us0 = _run_us(task, cfg)
        rows.append(("population/fedavg_round_unsharded", us0, f"n={n}_clients"))
        rows.append(("population/fedavg_round_sharded", us0,
                     "single_device_fallback"))
        print("  < 8 devices: sharded arms fall back to single-device")

    # memory scaling: per-device share of the staged client-axis batch stack.
    # The staged xs is THE population-proportional allocation (params/opt
    # state are tiny beside it at scale); 1/D per device => max population
    # scales with mesh size.
    for n_mem in (16, 32) if quick else (16, 32, 64):
        t_mem = _population_task(n_mem, 1024)
        c_mem = FedAvgConfig(rounds=2, local_steps=4, eval_every=100,
                             chunk_rounds=2, seed=0,
                             mesh=mesh if sharded else None)
        plan, _, _ = _fedavg_scan_plan(t_mem, t_mem.source, c_mem)
        import numpy as np

        idxs = np.flatnonzero(np.asarray(plan.trained))
        t0 = time.time()
        xs_put = plan.xs_put if plan.xs_put is not None else jax.device_put
        xs = xs_put(plan.stage(idxs))
        jax.block_until_ready(jax.tree.leaves(xs))
        us_stage = (time.time() - t0) * 1e6
        per_dev = _per_device_bytes(xs["batch"])
        tot = _global_bytes(xs["batch"])
        rows.append((f"population/staged_batch_n{n_mem}", us_stage,
                     f"per_device_B={per_dev}_of_{tot}"))
        print(f"  staged batch n={n_mem}: {per_dev / 1e6:.2f} MB/device of "
              f"{tot / 1e6:.2f} MB global ({tot / per_dev:.1f}x headroom)")

    # seed-axis sharding: the sweep's leading axis over the whole mesh
    seeds = range(8)
    sweep_cfg = FedAvgConfig(rounds=rounds, local_steps=4, eval_every=100,
                             chunk_rounds=rounds)
    run_sweep(task, sweep_cfg, seeds)
    t0 = time.time()
    run_sweep(task, sweep_cfg, seeds)
    us_sw0 = (time.time() - t0) / rounds * 1e6
    if sharded:
        run_sweep(task, sweep_cfg, seeds, mesh=mesh)
        t0 = time.time()
        run_sweep(task, sweep_cfg, seeds, mesh=mesh)
        us_sw1 = (time.time() - t0) / rounds * 1e6
        rows.append(("population/sweep_seed_sharded", us_sw1,
                     f"{us_sw0 / us_sw1:.2f}x_vs_unsharded_8seeds"))
        print(f"  sweep 8 seeds: unsharded {us_sw0:.0f} us/round  sharded "
              f"{us_sw1:.0f} us/round")
    else:
        rows.append(("population/sweep_seed_sharded", us_sw0,
                     "single_device_fallback"))
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", default=True)
    ap.add_argument("--full", dest="quick", action="store_false")
    args = ap.parse_args()

    rows = run(quick=args.quick)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    for name, _us, derived in rows:
        if name == "population/fedavg_round_sharded" and derived.endswith(
                "x_vs_unsharded"):
            s = float(derived.split("x")[0])
            if s < GATE:
                print(f"PERF REGRESSION: {name}: {s:.2f}x < {GATE:.2f}x "
                      "vs unsharded", file=sys.stderr)
                sys.exit(1)


if __name__ == "__main__":
    main()
