"""Serve a small LM with batched requests: prefill + token-by-token decode
through the production cache machinery (ring buffers, GQA caches).

  PYTHONPATH=src python examples/serve_decode.py --arch qwen3-0.6b --tokens 32
(arch resolves to its reduced smoke variant so this runs on CPU in seconds)
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, smoke_config
from repro.data.tokens import synthetic_lm_batch
from repro.models import transformer as tf


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=list(ARCH_IDS))
    ap.add_argument("--batch", type=int, default=4, help="concurrent requests")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32, help="tokens to generate")
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    B = args.batch
    batch = synthetic_lm_batch(cfg.vocab_size, B, args.prompt_len, seed=0)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(1), (B, cfg.num_audio_frames, cfg.d_model)) * 0.1

    capacity = args.prompt_len + args.tokens
    enc_len = cfg.num_audio_frames if cfg.is_encoder_decoder else 0
    caches = tf.init_caches(cfg, B, capacity, enc_len=enc_len)
    if cfg.is_encoder_decoder:
        caches = tf._fill_cross_caches(cfg, params, batch, caches)

    step = jax.jit(lambda p, c, t: tf.decode_step(cfg, p, c, t))

    # prefill by teacher-forced ingestion (reference path; production prefill
    # is the forward lowering in launch/steps.py)
    t0 = time.time()
    for t in range(args.prompt_len):
        logits, caches = step(params, caches, batch["tokens"][:, t : t + 1])
    prefill_s = time.time() - t0

    # greedy decode
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for _ in range(args.tokens - 1):
        logits, caches = step(params, caches, tok)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    decode_s = time.time() - t0
    gen = np.concatenate([np.asarray(t) for t in out], axis=1)

    print(f"arch={cfg.name} (reduced) | {B} requests | prompt {args.prompt_len} | "
          f"generated {args.tokens}")
    print(f"prefill: {prefill_s:.2f}s   decode: {decode_s:.2f}s "
          f"({B * (args.tokens - 1) / max(decode_s, 1e-9):.1f} tok/s)")
    for b in range(min(B, 2)):
        print(f"request {b}: {gen[b][:16].tolist()} ...")


if __name__ == "__main__":
    main()
