"""Guided tour of the participation subsystem (repro.part).

Runs Fed-CHS three ways on the same non-IID task and fixed seed:
  1. full participation (the bit-identical default path),
  2. bursty Gilbert-Elliott churn with an availability-aware sampler,
  3. the same churn with the availability-aware scheduler, so the 2-step
     rule itself routes around dark clusters;
then replays (2) through netsim with a per-interaction reporting deadline:
stragglers get dropped (bits saved), the aggregator waits (time wasted).

  PYTHONPATH=src python examples/participation_tour.py
"""
from __future__ import annotations

from repro.core import FedCHSConfig, FLTask, run_fed_chs
from repro.core.ledger import dense_message_bits
from repro.data import assign_clusters, dirichlet_partition, make_dataset
from repro.models.classifier import make_classifier
from repro.netsim import edge_cloud_network, sgd_step_flops, simulate_run
from repro.part import AvailabilityAware, GilbertElliottTrace


def main() -> None:
    ds = make_dataset("mnist", train_size=3000, test_size=800, seed=0)
    clients = dirichlet_partition(ds.train_y, 15, 0.6, seed=0)
    clusters = assign_clusters(15, 5, seed=0)
    model = make_classifier("mlp", "mnist", ds.spec.image_shape, 10)
    task = FLTask(model, ds, clients, clusters, batch_size=32, seed=0)

    T, K, E = 30, 8, 2
    trace = GilbertElliottTrace(p_fail=0.25, p_recover=0.35, seed=5)
    sampler = AvailabilityAware(trace)
    print(f"Gilbert-Elliott churn: steady-state up fraction "
          f"{trace.steady_state_up():.2f}, mean outage "
          f"{1 / trace.p_recover:.1f} rounds\n")

    arms = {
        "full participation": FedCHSConfig(rounds=T, local_steps=K,
                                           local_epochs=E, eval_every=5, seed=0),
        "churn": FedCHSConfig(rounds=T, local_steps=K, local_epochs=E,
                              eval_every=5, seed=0, sampler=sampler),
        "churn + availability scheduler": FedCHSConfig(
            rounds=T, local_steps=K, local_epochs=E, eval_every=5, seed=0,
            sampler=sampler, availability_scheduler=True),
    }
    results = {}
    for name, cfg in arms.items():
        res = run_fed_chs(task, cfg)
        results[name] = res
        up = res.ledger.round_bits("client_to_es")
        dark = len([t for t in range(T) if up.get(t, 0) == 0])
        print(f"{name:32s} final acc {res.final_acc():.3f}  "
              f"uplink {res.ledger.bits['client_to_es'] / 8e6:7.1f} MB  "
              f"pass-through rounds {dark}")

    # the deadline replay: same churn run, straggler-heavy edge network
    net = edge_cloud_network(seed=2, heterogeneity=0.3, straggler_frac=0.25,
                             straggler_slowdown=16.0)
    d, q = task.num_params(), dense_message_bits(task.num_params())
    nominal = net.nominal_chain_s("wireless", q,
                                  E * sgd_step_flops(d, task.batch_size))
    churn = results["churn"]
    no_dl = simulate_run(task, churn, net, local_steps=K)
    with_dl = simulate_run(task, churn, net, local_steps=K,
                           deadline_s=3.0 * nominal)
    n_dropped = sum(len(s) for s in with_dl.dropped.values())
    print("\nnetsim replay of the churn run (straggler edge):")
    print(f"  no deadline:   makespan {no_dl.makespan:8.1f} s")
    print(f"  3x-nominal deadline: makespan {with_dl.makespan:8.1f} s, "
          f"{n_dropped} client-rounds dropped, "
          f"{with_dl.dropped_bits / 8e6:.1f} MB of uplink saved")
    print("\nDropouts saved bits AND time here because the dropped chains were"
          "\n16x stragglers; the aggregator still waited out each deadline.")


if __name__ == "__main__":
    main()
