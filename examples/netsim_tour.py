"""Tour of repro.netsim: from a bit ledger to wall-clock time-to-accuracy.

  PYTHONPATH=src python examples/netsim_tour.py

The paper's §3.2 overhead model counts bits per hop — network-independent by
construction.  netsim adds the physical layer: link models per hop class,
per-node compute speeds, and a deterministic event-driven simulator that
replays a training run's recorded message stream (who sent what to whom, in
which interaction of which round) into timestamps.  One training run can be
re-timed under any number of networks, including time-varying IoV/LEO
topologies and a latency-aware variant of the paper's 2-step scheduler.
"""
from repro.core import FedCHSConfig, FLTask, run_fed_chs
from repro.core.baselines import FedAvgConfig, run_fedavg
from repro.core.dynamics import make_dynamic
from repro.core.ledger import dense_message_bits
from repro.data import assign_clusters, dirichlet_partition, make_dataset
from repro.netsim import edge_cloud_network, simulate_run, time_to_accuracy


def main():
    # -- 1. a small non-IID task and two recorded training runs ------------
    ds = make_dataset("mnist", train_size=3000, test_size=600, seed=0)
    clients = dirichlet_partition(ds.train_y, num_clients=20, alpha=0.6, seed=0)
    clusters = assign_clusters(num_clients=20, num_clusters=4, seed=0)
    from repro.models.classifier import make_classifier

    model = make_classifier("mlp", "mnist", ds.spec.image_shape, num_classes=10)
    task = FLTask(model, ds, clients, clusters, batch_size=32, seed=0)

    K = 10
    chs = run_fed_chs(task, FedCHSConfig(rounds=20, local_steps=K, eval_every=1))
    avg = run_fedavg(task, FedAvgConfig(rounds=8, local_steps=K, eval_every=1))
    print(f"recorded {len(chs.ledger.events)} Fed-CHS messages, "
          f"{len(avg.ledger.events)} FedAvg messages")

    # -- 2. replay both runs through two very different networks -----------
    nets = {
        "edge_cloud (paper's sketch)": edge_cloud_network(seed=0),
        "wan_starved (PS 50x slower)": edge_cloud_network(seed=0, wan_mbps=2.0,
                                                          wan_latency_ms=80.0),
    }
    gamma = 0.9

    def fmt(t):  # time_to_accuracy returns None when gamma was never reached
        return "never" if t is None else f"{t:.1f}s"

    for name, net in nets.items():
        t_chs = time_to_accuracy(chs, simulate_run(task, chs, net, local_steps=K), gamma)
        t_avg = time_to_accuracy(avg, simulate_run(task, avg, net, local_steps=K), gamma)
        print(f"{name}: time-to-{gamma:.0%}  fed_chs={fmt(t_chs)}  fedavg={fmt(t_avg)}")
    print("-> same bits, different clocks: the winner is a property of the "
          "network, which bit counting alone cannot see.")

    # -- 3. stragglers hurt the parallel round more than the serial one ----
    strag = edge_cloud_network(seed=0, straggler_frac=0.1, heterogeneity=0.3,
                               straggler_slowdown=16.0)
    tl_chs = simulate_run(task, chs, strag, local_steps=K)
    tl_avg = simulate_run(task, avg, strag, local_steps=K)
    chs_rounds = [tl_chs.round_duration(t) for t in sorted(tl_chs.round_end)]
    avg_rounds = [tl_avg.round_duration(t) for t in sorted(tl_avg.round_end)]
    print(f"straggler net: fed_chs rounds {min(chs_rounds):.2f}-{max(chs_rounds):.2f}s "
          "(straggler-free clusters stay fast), fedavg rounds "
          f"{min(avg_rounds):.2f}-{max(avg_rounds):.2f}s (every round waits for "
          "the slowest of ALL clients)")

    # -- 4. time-varying links: a flaky IoV backhaul costs time, not bits --
    dyn = make_dynamic("iov", task.num_clusters, seed=1)
    iov = edge_cloud_network(seed=0, backhaul_mbps=20.0, dynamics=dyn)
    clean = edge_cloud_network(seed=0, backhaul_mbps=20.0)
    chs_dyn = run_fed_chs(task, FedCHSConfig(rounds=20, local_steps=K, eval_every=1,
                                             dynamic="iov", topology_seed=1))
    tl = simulate_run(task, chs_dyn, iov, local_steps=K)
    flat = simulate_run(task, chs_dyn, clean, local_steps=K)
    print(f"IoV fading (20 Mbps RSU backhaul): makespan {tl.makespan:.1f}s vs "
          f"{flat.makespan:.1f}s on clean links — identical ledger "
          f"({chs_dyn.ledger.total_megabytes():.0f} MB): flaky links cost "
          "time, not bits")

    # -- 5. the latency-aware 2-step scheduler routes around slow links ----
    # a full ES mesh leaves the least-traversed rule with frequent ties; the
    # paper breaks them by dataset size, the latency-aware variant by link
    # delay — on a backhaul with 1-10x per-pair spread that choice shows up
    # directly in the serial chain's wall-clock
    q = dense_message_bits(task.num_params())
    spread_net = edge_cloud_network(seed=0, backhaul_mbps=20.0, backhaul_spread=9.0)
    base = run_fed_chs(task, FedCHSConfig(rounds=20, local_steps=K, eval_every=1,
                                          topology="full"))
    lat = run_fed_chs(task, FedCHSConfig(rounds=20, local_steps=K, eval_every=1,
                                         topology="full",
                                         link_delay=spread_net.link_delay_fn(q)))
    t_base = simulate_run(task, base, spread_net, local_steps=K).makespan
    t_aware = simulate_run(task, lat, spread_net, local_steps=K).makespan
    print("heterogeneous backhaul (1-10x per-link delay, full mesh): 2-step "
          f"rule {t_base:.1f}s vs latency-aware tie-break {t_aware:.1f}s "
          f"(final acc {base.final_acc():.3f} vs {lat.final_acc():.3f})")


if __name__ == "__main__":
    main()
