"""Federated transformer-LM pretraining with Fed-CHS — on the unified stack.

This used to be a side-path that called the raw transformer train step and
bypassed the round engine, the compression channels, the bit ledger, and the
network simulator.  It is now an `LMFedModel` + `TokenSource` FedTask driven
by the same `run_fed_chs` as the paper's MLP/LeNet experiments, which buys
the LM workload everything the classifier path already had:

  * QSGD/Top-K compressed uplinks (pick with --qsgd / --topk);
  * bit-exact per-message `CommLedger` accounting + `CommEvent` streams;
  * `repro.netsim` replay: simulated wall-clock time-to-perplexity under a
    configurable edge network;
  * client-held local optimizers (--adamw keeps AdamW moments on-device —
    uplink bits are identical to plain SGD).

Each client's token stream is non-IID (topic-skewed Markov chains over a
shared transition table), and every batch draw is keyed by
``(seed, client, draw_index)`` — the stream position is explicit, so a
resumed run replays the exact schedule of batches instead of resampling
from scratch (the old `batch_for(round_idx)` ignored its argument).

Defaults are CPU-sized (a few minutes).  Scale up with e.g.:
  PYTHONPATH=src python examples/train_lm_fedchs.py --d-model 768 --layers 12 \
      --vocab 32768 --seq 256 --batch 8 --rounds 300
"""
import argparse
import time

from repro.comm.channels import DenseChannel, QSGDChannel, TopKChannel
from repro.configs.base import ArchConfig
from repro.core import FedCHSConfig, run_fed_chs
from repro.core.simulation import FLTask
from repro.data.sources import TokenSource
from repro.models.fed import LMFedModel
from repro.netsim.adapters import simulate_run, time_to_accuracy
from repro.netsim.links import NetworkModel
from repro.optim.local import AdamWOpt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4, help="per-client batch")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--clusters", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--local-steps", type=int, default=4, help="K in-cluster steps/round")
    ap.add_argument("--local-epochs", type=int, default=2, help="E steps per upload")
    ap.add_argument("--qsgd", type=int, default=16,
                    help="QSGD levels for the client->ES uplink (0 = dense)")
    ap.add_argument("--topk", type=float, default=0.0,
                    help="Top-K uplink fraction (overrides --qsgd when > 0)")
    ap.add_argument("--adamw", action="store_true",
                    help="client-held AdamW instead of plain SGD")
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--eval-every", type=int, default=5)
    ap.add_argument("--target-ppl", type=float, default=40.0,
                    help="perplexity threshold for the time-to-loss replay")
    args = ap.parse_args()

    cfg = ArchConfig(
        name="fedchs-lm", family="dense", num_layers=args.layers, d_model=args.d_model,
        num_heads=max(args.d_model // 64, 1), num_kv_heads=max(args.d_model // 128, 1),
        d_ff=4 * args.d_model, vocab_size=args.vocab, dtype="float32",
    )
    model = LMFedModel(cfg)
    source = TokenSource(args.vocab, args.clients, args.batch, args.seq,
                         topics=args.clusters * 2, seed=0)
    members = [[i for i in range(args.clients) if i % args.clusters == m]
               for m in range(args.clusters)]
    task = FLTask.from_source(model, source, members, seed=0)
    print(f"model: {args.layers}L d={args.d_model} -> {task.num_params()/1e6:.1f}M params, "
          f"{args.clients} clients / {args.clusters} ES clusters")

    if args.topk > 0:
        channel = TopKChannel(fraction=args.topk)
    elif args.qsgd > 0:
        channel = QSGDChannel(args.qsgd)
    else:
        channel = DenseChannel()
    config = FedCHSConfig(
        rounds=args.rounds, local_steps=args.local_steps, local_epochs=args.local_epochs,
        eval_every=args.eval_every, channel=channel, seed=0,
        local_opt=AdamWOpt(weight_decay=0.0) if args.adamw else None,
        schedule=lambda k: args.lr,
    )

    t0 = time.time()
    res = run_fed_chs(task, config)
    wall = time.time() - t0
    for r, ppl, loss in zip(res.rounds, res.test_acc, res.train_loss):
        print(f"round {r:4d}  train loss {loss:.4f}  held-out ppl {ppl:8.2f}")
    print(f"done in {wall:.0f}s — uniform vocab ppl would be {args.vocab}")

    mb = res.ledger.total_megabytes()
    print(f"\ncommunication: {mb:,.1f} MB total "
          f"({channel.__class__.__name__} uplink)")
    for hop, bits in res.ledger.breakdown().items():
        print(f"  {hop:15s} {bits / 8 / 1e6:10.1f} MB")

    timeline = simulate_run(task, res, NetworkModel(), local_steps=args.local_steps)
    tta = time_to_accuracy(res, timeline, args.target_ppl)
    print(f"\nnetsim replay (default edge network): one pass of this run takes "
          f"{timeline.makespan:,.1f}s of simulated wall-clock")
    if tta is None:
        print(f"never reached ppl <= {args.target_ppl}; best {res.best_acc():.2f} "
              "(raise --rounds or --lr)")
    else:
        print(f"time to ppl <= {args.target_ppl}: {tta:,.1f}s simulated")


if __name__ == "__main__":
    main()
