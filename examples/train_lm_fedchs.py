"""Federated transformer-LM pretraining with Fed-CHS — on the unified stack.

This used to be a side-path that called the raw transformer train step and
bypassed the round engine, the compression channels, the bit ledger, and the
network simulator.  It is now an `LMFedModel` + `TokenSource` FedTask driven
by the same `run_fed_chs` as the paper's MLP/LeNet experiments, which buys
the LM workload everything the classifier path already had:

  * QSGD/Top-K compressed uplinks (pick with --qsgd / --topk);
  * bit-exact per-message `CommLedger` accounting + `CommEvent` streams;
  * `repro.netsim` replay: simulated wall-clock time-to-perplexity under a
    configurable edge network;
  * client-held local optimizers (--adamw keeps AdamW moments on-device —
    uplink bits are identical to plain SGD).

Each client's token stream is non-IID (topic-skewed Markov chains over a
shared transition table), and every batch draw is keyed by
``(seed, client, draw_index)`` — the stream position is explicit, so a
resumed run replays the exact schedule of batches instead of resampling
from scratch (the old `batch_for(round_idx)` ignored its argument).

Defaults are CPU-sized (a few minutes).  Scale up with e.g.:
  PYTHONPATH=src python examples/train_lm_fedchs.py --d-model 768 --layers 12 \
      --vocab 32768 --seq 256 --batch 8 --rounds 300

--config <arch-id> swaps the hand-rolled dims for a registry architecture
and turns on the memory-lean engine configuration (bf16 compute + f32
master + bf16 dense wire, gradient rematerialization, and whatever
--client-microbatch you pass).  This is the 0.6B-client-scale entry point:

  PYTHONPATH=src python examples/train_lm_fedchs.py \
      --config qwen3_0_6b --client-microbatch 1

completes one full Fed-CHS round of qwen3-0.6b clients on a single host —
the microbatched engine holds ONE client's bf16 training state at a time,
so peak memory is model-sized, not population-sized (documented budget:
<= 24 GB peak RSS on CPU; see README "Memory model & mixed precision").
Config-mode defaults are one round of 2 clients / 2 clusters at batch 1,
seq 128 — every knob stays overridable.
"""
import argparse
import re
import resource
import time

from repro.comm.channels import DenseChannel, QSGDChannel, TopKChannel
from repro.configs.base import ArchConfig
from repro.core import FedCHSConfig, run_fed_chs
from repro.core.precision import Precision
from repro.core.simulation import FLTask
from repro.data.sources import TokenSource
from repro.models.fed import LMFedModel
from repro.netsim.adapters import simulate_run, time_to_accuracy
from repro.netsim.links import NetworkModel
from repro.optim.local import AdamWOpt

# documented peak-RSS budget for the --config qwen3_0_6b --client-microbatch 1
# acceptance run (master params 2.4 GB f32 + one client's bf16 compute state
# + XLA compile workspace, measured on CPU with headroom)
QWEN3_BUDGET_GB = 24.0


def _resolve_arch(name: str):
    """Registry id lookup, tolerant of -/_/. spelling (qwen3_0_6b works)."""
    import dataclasses

    from repro.configs.registry import ARCH_IDS, get_config

    key = re.sub(r"[^a-z0-9]", "", name.lower())
    for arch_id in ARCH_IDS:
        if re.sub(r"[^a-z0-9]", "", arch_id) == key:
            # f32 params: the run state IS the master copy under the
            # mixed-precision policy (the engine casts down per round)
            return arch_id, dataclasses.replace(get_config(arch_id),
                                                dtype="float32")
    raise SystemExit(f"unknown --config {name!r}; choose from {ARCH_IDS}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", default=None, metavar="ARCH",
                    help="registry architecture id (e.g. qwen3_0_6b); "
                         "overrides --d-model/--layers/--vocab and turns on "
                         "the memory-lean defaults (bf16 compute, f32 "
                         "master, remat, 1 round of 2 clients)")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=None, help="per-client batch")
    ap.add_argument("--clients", type=int, default=None)
    ap.add_argument("--clusters", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--local-steps", type=int, default=None,
                    help="K in-cluster steps/round")
    ap.add_argument("--local-epochs", type=int, default=None,
                    help="E steps per upload")
    ap.add_argument("--client-microbatch", type=int, default=None,
                    help="clients trained simultaneously per round (None = "
                         "all at once); 1 is the memory-lean setting")
    ap.add_argument("--mixed-precision", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="bf16 compute / f32 master / bf16 dense wire "
                         "(default: on with --config, off otherwise)")
    ap.add_argument("--remat", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="gradient rematerialization (default: on with "
                         "--config, off otherwise)")
    ap.add_argument("--qsgd", type=int, default=None,
                    help="QSGD levels for the client->ES uplink (0 = dense; "
                         "default 16, or 0 with --config where the bf16 "
                         "dense wire takes over)")
    ap.add_argument("--topk", type=float, default=0.0,
                    help="Top-K uplink fraction (overrides --qsgd when > 0)")
    ap.add_argument("--adamw", action="store_true",
                    help="client-held AdamW instead of plain SGD")
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--eval-every", type=int, default=None)
    ap.add_argument("--target-ppl", type=float, default=40.0,
                    help="perplexity threshold for the time-to-loss replay")
    args = ap.parse_args()

    lean = args.config is not None
    # config mode defaults to ONE memory-budgeted round at LM scale; toy mode
    # keeps the historical few-minute CPU run
    rounds = args.rounds if args.rounds is not None else (1 if lean else 40)
    local_steps = args.local_steps if args.local_steps is not None else \
        (2 if lean else 4)
    local_epochs = args.local_epochs if args.local_epochs is not None else \
        (1 if lean else 2)
    batch = args.batch if args.batch is not None else (1 if lean else 4)
    clients = args.clients if args.clients is not None else (2 if lean else 4)
    eval_every = args.eval_every if args.eval_every is not None else \
        (1 if lean else 5)
    qsgd = args.qsgd if args.qsgd is not None else (0 if lean else 16)
    mixed = args.mixed_precision if args.mixed_precision is not None else lean
    remat = args.remat if args.remat is not None else lean

    if lean:
        arch_id, cfg = _resolve_arch(args.config)
        print(f"arch {arch_id}: {cfg.num_layers}L d={cfg.d_model} "
              f"vocab={cfg.vocab_size}")
    else:
        cfg = ArchConfig(
            name="fedchs-lm", family="dense", num_layers=args.layers,
            d_model=args.d_model, num_heads=max(args.d_model // 64, 1),
            num_kv_heads=max(args.d_model // 128, 1), d_ff=4 * args.d_model,
            vocab_size=args.vocab, dtype="float32",
        )
    model = LMFedModel(cfg, remat=remat)
    source = TokenSource(cfg.vocab_size, clients, batch, args.seq,
                         topics=args.clusters * 2, seed=0)
    members = [[i for i in range(clients) if i % args.clusters == m]
               for m in range(args.clusters)]
    task = FLTask.from_source(model, source, members, seed=0)
    precision = Precision() if mixed else None
    print(f"model: {cfg.num_layers}L d={cfg.d_model} -> "
          f"{task.num_params()/1e6:.1f}M params, "
          f"{clients} clients / {args.clusters} ES clusters"
          + (f", microbatch={args.client_microbatch}"
             if args.client_microbatch else "")
          + (", bf16 compute / f32 master" if mixed else ""))

    if args.topk > 0:
        channel = TopKChannel(fraction=args.topk)
    elif qsgd > 0:
        channel = QSGDChannel(qsgd)
    elif precision is None:
        channel = DenseChannel()
    else:
        channel = None  # FedCHSConfig resolves the bf16 dense wire
    config = FedCHSConfig(
        rounds=rounds, local_steps=local_steps, local_epochs=local_epochs,
        eval_every=eval_every, channel=channel, seed=0,
        precision=precision, client_microbatch=args.client_microbatch,
        local_opt=AdamWOpt(weight_decay=0.0) if args.adamw else None,
        schedule=lambda k: args.lr,
    )

    t0 = time.time()
    res = run_fed_chs(task, config)
    wall = time.time() - t0
    for r, ppl, loss in zip(res.rounds, res.test_acc, res.train_loss):
        print(f"round {r:4d}  train loss {loss:.4f}  held-out ppl {ppl:8.2f}")
    print(f"done in {wall:.0f}s — uniform vocab ppl would be {cfg.vocab_size}")

    peak_gb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1e6
    budget = f" (budget <= {QWEN3_BUDGET_GB:.0f} GB)" if lean else ""
    print(f"peak RSS: {peak_gb:.1f} GB{budget}")
    if lean and peak_gb > QWEN3_BUDGET_GB:
        print(f"WARNING: over the documented {QWEN3_BUDGET_GB:.0f} GB budget")

    from repro.core.precision import resolve_channel

    mb = res.ledger.total_megabytes()
    resolved = resolve_channel(precision, channel)
    wire = getattr(resolved, "wire_dtype", None)
    ch_name = resolved.__class__.__name__ + (f"[{wire}]" if wire else "")
    print(f"\ncommunication: {mb:,.1f} MB total ({ch_name} uplink)")
    for hop, bits in res.ledger.breakdown().items():
        print(f"  {hop:15s} {bits / 8 / 1e6:10.1f} MB")

    timeline = simulate_run(task, res, NetworkModel(), local_steps=local_steps)
    tta = time_to_accuracy(res, timeline, args.target_ppl)
    print(f"\nnetsim replay (default edge network): one pass of this run takes "
          f"{timeline.makespan:,.1f}s of simulated wall-clock")
    if tta is None:
        print(f"never reached ppl <= {args.target_ppl}; best {res.best_acc():.2f} "
              "(raise --rounds or --lr)")
    else:
        print(f"time to ppl <= {args.target_ppl}: {tta:,.1f}s simulated")


if __name__ == "__main__":
    main()
