"""End-to-end driver: train a transformer LM with the Fed-CHS protocol.

Two Fed-CHS chains (clusters) train on disjoint non-IID token streams; after
every round the models pass sequentially between clusters (Algorithm 1 —
here with C=2 the ring the 2-step rule produces). Loss is reported per chain.

Defaults are CPU-sized (~20M params, 150 rounds, ~10 min). For the ~100M-param
run use:
  PYTHONPATH=src python examples/train_lm_fedchs.py --d-model 768 --layers 12 \
      --rounds 300 --batch 8
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.data.tokens import MarkovTokens
from repro.launch.steps import make_train_round
from repro.models import transformer as tf
from repro.optim.schedules import paper_sqrt_schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--d-model", type=int, default=384)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8, help="per-chain batch")
    ap.add_argument("--rounds", type=int, default=150)
    ap.add_argument("--chains", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-2)
    ap.add_argument("--eval-every", type=int, default=25)
    args = ap.parse_args()

    cfg = ArchConfig(
        name="fedchs-lm", family="dense", num_layers=args.layers, d_model=args.d_model,
        num_heads=max(args.d_model // 64, 1), num_kv_heads=max(args.d_model // 128, 1),
        d_ff=4 * args.d_model, vocab_size=args.vocab, dtype="float32",
    )
    n_params = cfg.param_count()
    print(f"model: {args.layers}L d={args.d_model} -> {n_params/1e6:.1f}M params")

    key = jax.random.PRNGKey(0)
    params = tf.init_params(cfg, key)
    C = args.chains
    stacked = jax.tree.map(lambda x: jnp.stack([x] * C), params)

    # per-cluster non-IID corpora: different Markov topic mixtures
    gens = [MarkovTokens(args.vocab, topics=4, seed=100 + c) for c in range(C)]
    rngs = [np.random.default_rng(c) for c in range(C)]

    def batch_for(round_idx):
        toks = np.stack(
            [g.sample(r, args.batch, args.seq + 1) for g, r in zip(gens, rngs)]
        )
        return {
            "tokens": jnp.asarray(toks[:, :, :-1]),
            "labels": jnp.asarray(toks[:, :, 1:]),
        }

    round_fn = jax.jit(make_train_round(cfg, variant="fedchs", remat=False),
                       donate_argnums=(0,))
    sched = paper_sqrt_schedule(K=20, half=False)

    t0 = time.time()
    for t in range(args.rounds):
        lr = jnp.float32(args.lr * sched(0) * 20)  # scale the paper schedule
        stacked, loss = round_fn(stacked, batch_for(t), lr)
        if t % args.eval_every == 0 or t == args.rounds - 1:
            tok_s = args.batch * args.seq * C * (t + 1) / (time.time() - t0)
            print(f"round {t:4d}  loss {float(loss):.4f}  ({tok_s:,.0f} tok/s)", flush=True)
    print(f"done in {time.time()-t0:.0f}s — chains converged on each other's data "
          "through sequential passing alone (no PS).")


if __name__ == "__main__":
    main()
