import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# must precede any jax import (the production mesh needs 512 host devices)

"""Launcher API tour: lower one architecture onto the 2-pod production mesh
with the Fed-CHS pod-sequential variant AND the HFL baseline, and print the
collective-bytes difference — the paper's communication claim, visible in HLO.

  PYTHONPATH=src python examples/multipod_dryrun.py --arch qwen3-0.6b
"""
import argparse

from repro.configs.registry import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_lowering, lower_spec
from repro.roofline.analysis import analyze_compiled, roofline_terms


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=list(ARCH_IDS))
    args = ap.parse_args()

    cfg = get_config(args.arch)
    mesh = make_production_mesh(multi_pod=True)
    print(f"mesh: {dict(mesh.shape)} = {mesh.devices.size} chips")

    results = {}
    for variant in ("fedchs", "hfl"):
        spec = build_lowering(cfg, "train_4k", mesh, variant=variant)
        compiled = lower_spec(spec, mesh).compile()
        rec = analyze_compiled(compiled)
        terms = roofline_terms(rec)
        results[variant] = rec
        print(f"\n[{variant}] bound={terms['bound']}  "
              f"compute={terms['compute_s']:.3e}s memory={terms['memory_s']:.3e}s "
              f"collective={terms['collective_s']:.3e}s")
        for op, b in sorted(rec["collectives"].items()):
            print(f"   {op:20s} {b/1e9:10.3f} GB/device")

    saved = (results["hfl"]["collective_bytes_per_device"]
             - results["fedchs"]["collective_bytes_per_device"])
    print(f"\nFed-CHS saves {saved/1e9:.3f} GB/device of collective traffic per round "
          "vs star-aggregated HFL (the paper's §5.3 claim, in lowered XLA).")


if __name__ == "__main__":
    main()
