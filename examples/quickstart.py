"""Quickstart: train a model with Fed-CHS on a non-IID synthetic MNIST in ~30s.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import FedCHSConfig, FLTask, run_fed_chs
from repro.data import assign_clusters, dirichlet_partition, make_dataset
from repro.models.classifier import make_classifier


def main():
    # 1. data: 20 clients with Dirichlet(0.6) label skew, 4 ES clusters
    ds = make_dataset("mnist", train_size=4000, test_size=1000, seed=0)
    clients = dirichlet_partition(ds.train_y, num_clients=20, alpha=0.6, seed=0)
    clusters = assign_clusters(num_clients=20, num_clusters=4, seed=0)

    # 2. model: the paper's MLP
    model = make_classifier("mlp", "mnist", ds.spec.image_shape, num_classes=10)

    # 3. run Fed-CHS (Algorithm 1): sequential cluster-by-cluster training,
    #    no parameter server, 2-step next-cluster rule over a sparse ES graph
    task = FLTask(model, ds, clients, clusters, batch_size=32, seed=0)
    cfg = FedCHSConfig(rounds=30, local_steps=10, topology="random_sparse", eval_every=5)
    result = run_fed_chs(task, cfg)

    print(f"accuracy trace : {[round(a, 3) for a in result.test_acc]}")
    print(f"final accuracy : {result.final_acc():.4f}")
    print(f"total comm     : {result.ledger.total_megabytes():.1f} MB")
    print(f"per-hop bits   : { {k: f'{v/8/1e6:.1f} MB' for k, v in result.ledger.breakdown().items()} }")
    print("note           : zero client<->PS and ES<->PS traffic — no PS exists.")


if __name__ == "__main__":
    main()
