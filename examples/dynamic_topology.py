"""Fed-CHS over time-varying networks — the paper's Appendix-D scenarios.

Trains the same non-IID task through three ES networks:
  * static random-sparse graph (the paper's main setting, Appendix B.1),
  * a rotating LEO constellation (the graph shifts every round),
  * an IoV roadside-unit line with flapping links (Gilbert-style drops).

The punchline of §1: the 2-step rule needs no topology assumptions, so
accuracy and communication are essentially unchanged while the network
churns underneath — and there is still zero PS traffic.

  PYTHONPATH=src python examples/dynamic_topology.py
"""
from repro.core import FedCHSConfig, FLTask, run_fed_chs
from repro.data import assign_clusters, dirichlet_partition, make_dataset
from repro.models.classifier import make_classifier


def main():
    ds = make_dataset("mnist", train_size=4000, test_size=1000, seed=0)
    clients = dirichlet_partition(ds.train_y, 20, 0.6, seed=0)
    clusters = assign_clusters(20, 5, seed=0)
    model = make_classifier("mlp", "mnist", ds.spec.image_shape, 10)
    task = FLTask(model, ds, clients, clusters, batch_size=32, seed=0)

    settings = {
        "static sparse": dict(topology="random_sparse", dynamic=None),
        "LEO rotating": dict(dynamic="leo"),
        "IoV flapping": dict(dynamic="iov"),
    }
    print(f"{'network':14s} {'final_acc':>9s} {'total_MB':>9s} {'ES->ES hops':>12s}")
    for name, kw in settings.items():
        res = run_fed_chs(task, FedCHSConfig(rounds=30, local_steps=10,
                                             eval_every=10, seed=0, **kw))
        print(f"{name:14s} {res.final_acc():9.4f} "
              f"{res.ledger.total_megabytes():9.1f} "
              f"{res.ledger.messages['es_to_es']:12d}")
    print("\nsame accuracy, same bits, one ES->ES hop per round — the 2-step "
          "rule never needed the graph to stand still.")


if __name__ == "__main__":
    main()
