"""Fed-CHS vs the paper's three baselines on one non-IID task: accuracy AND
communication cost side-by-side (the paper's Table 1 + Fig. 2 in miniature),
plus a Fed-CHS arm over the Top-K sparsifying channel — a compression scheme
the paper never ran, enabled for free by the pluggable channel stack.

  PYTHONPATH=src python examples/compare_algorithms.py [--lam 0.3]
"""
import argparse

from repro.comm import TopKChannel
from repro.core import FedCHSConfig, FLTask, run_fed_chs
from repro.core.baselines import (
    FedAvgConfig, HierLocalQSGDConfig, WRWGDConfig,
    run_fedavg, run_hier_local_qsgd, run_wrwgd,
)
from repro.data import assign_clusters, dirichlet_partition, make_dataset
from repro.models.classifier import make_classifier


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lam", type=float, default=0.3, help="Dirichlet concentration")
    ap.add_argument("--dataset", default="mnist", choices=["mnist", "cifar10", "cifar100"])
    ap.add_argument("--model", default="mlp", choices=["mlp", "lenet"])
    args = ap.parse_args()

    ds = make_dataset(args.dataset, train_size=4000, test_size=1000, seed=0)
    clients = dirichlet_partition(ds.train_y, 20, args.lam, seed=0)
    clusters = assign_clusters(20, 5, seed=0)
    model = make_classifier(args.model, args.dataset, ds.spec.image_shape, ds.spec.num_classes)
    task = FLTask(model, ds, clients, clusters, batch_size=32, seed=0)

    runs = {
        "Fed-CHS": run_fed_chs(task, FedCHSConfig(rounds=24, local_steps=10, eval_every=6)),
        "FedAvg": run_fedavg(task, FedAvgConfig(rounds=6, local_steps=10, eval_every=2)),
        "WRWGD": run_wrwgd(task, WRWGDConfig(rounds=48, local_steps=10, eval_every=12)),
        "Hier-Local-QSGD": run_hier_local_qsgd(
            task, HierLocalQSGDConfig(rounds=4, local_steps=10, local_epochs=5, eval_every=1)
        ),
        "Fed-CHS (Top-5%)": run_fed_chs(
            task, FedCHSConfig(rounds=24, local_steps=10, local_epochs=5, eval_every=6,
                               channel=TopKChannel(0.05))
        ),
    }
    print(f"\n{args.dataset}/{args.model}, Dirichlet({args.lam}) — 20 clients, 5 ES")
    print(f"{'algorithm':18s} {'final_acc':>9s} {'total_MB':>9s} {'PS traffic MB':>14s}")
    for name, res in runs.items():
        ps = (res.ledger.bits["es_to_ps"] + res.ledger.bits["ps_to_es"]
              + res.ledger.bits["client_to_ps"] + res.ledger.bits["ps_to_client"]) / 8 / 1e6
        print(f"{name:18s} {res.final_acc():9.4f} {res.ledger.total_megabytes():9.1f} "
              f"{ps:14.1f}")


if __name__ == "__main__":
    main()
