import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# must precede any jax import (the production mesh needs 512 host devices)

"""Tour of the MoE optimization stack (EXPERIMENTS.md §Perf pair 1).

Lowers deepseek-v3-671b x train_4k on the 256-chip production mesh twice:
  * paper-faithful baseline — global expert-choice routing, GSPMD infers all
    communication; the combine scatter-add resolves as operand-replicated +
    a full-activation all-reduce (~TB/device);
  * --opt configuration — group-limited routing + the `jax.shard_map`
    interior (models/moe_shardmap.py) whose only communication is a
    per-layer (n_loc, d) psum over `model`.
and prints the roofline terms + top collective sources of each.

Takes ~1 min (two AOT compiles of a 61-layer model).

  PYTHONPATH=src python examples/moe_shardmap_tour.py [--arch dbrx-132b]
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v3-671b",
                    choices=["deepseek-v3-671b", "dbrx-132b"])
    args = ap.parse_args()

    from repro.configs.registry import get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_lowering, lower_spec
    from repro.roofline.analysis import analyze_compiled, roofline_terms
    from repro.roofline.attribution import collective_breakdown

    cfg = get_config(args.arch)
    mesh = make_production_mesh()
    results = {}
    for name, optimized in (("baseline", False), ("+opt(shard_map)", True)):
        spec = build_lowering(cfg, "train_4k", mesh, optimized=optimized)
        compiled = lower_spec(spec, mesh).compile()
        rec = analyze_compiled(compiled)
        terms = roofline_terms(rec)
        results[name] = rec
        print(f"\n[{name}] bound={terms['bound']}  "
              f"compute={terms['compute_s']:.1f}s memory={terms['memory_s']:.1f}s "
              f"collective={terms['collective_s']:.1f}s")
        for row in collective_breakdown(compiled.as_text(), top=3):
            print(f"   {row['bytes']/1e9:8.1f} GB/dev  {row['op']:18s} "
                  f"{row['shape'][:40]:40s} <- ...{row['source'][-45:]}")

    ratio = (results["baseline"]["collective_bytes_per_device"]
             / max(results["+opt(shard_map)"]["collective_bytes_per_device"], 1))
    print(f"\nThe optimized interior moves {ratio:.1f}x fewer collective bytes per "
          "step (EXPERIMENTS.md §Perf, iterations 1-5).")


if __name__ == "__main__":
    main()
